"""Trace model configs into TASKGRAPHs (paper §4: "TURNIP is agnostic as to
how the TASKGRAPH is created" — this module plays the FlexFlow/Alpa role).

Two workloads, matching the paper's evaluation:

* :func:`trace_prefill` — first-token inference (paper §8 task 1): per layer,
  per device, head-sliced q/k/v projections, per-(head-group × q-row-block)
  attention fragments (the 128·n² offloadable intermediates of the paper's
  introduction), row-sliced output projections combined with a *streaming*
  reduction (§B), column-sliced MLP. Weights are INPUT vertices → they
  stream from host RAM exactly like the paper's weight offload.
* :func:`trace_lora_train` — LoRA fwd+bwd (paper §8 task 2): rank-r adapters
  on Q/K/V and the FFN up-projection, frozen base weights, activation
  checkpointing (only layer inputs saved; each layer's internals are
  re-traced in the backward section, as the paper does). The backward math
  is *exact* — validated against ``jax.grad`` of an identical reference
  network in the test suite.

Head-batched attention keeps per-head softmax semantics while letting one
task cover ``head_group`` heads ([hg, qb, S] tensors), so vertex counts stay
tractable at paper scale without under-counting the quadratic memory.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import numpy as np

from .taskgraph import OpKind, TaskGraph, TensorSpec
from ..configs.base import ArchConfig

__all__ = ["TraceConfig", "Traced", "trace_prefill", "trace_lora_train"]


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_devices: int = 1
    head_group: int = 4          # heads fused per attention task (exact math)
    q_block: int = 1024          # q rows per attention task
    mlp_slices: int = 4          # column slices of the FFN per device
    lora_rank: int = 16
    lora_alpha: float = 16.0
    dtype: str = "float32"       # float16 for memory-faithful benchmarks


@dataclasses.dataclass
class Traced:
    tg: TaskGraph
    weight_tids: list[int]
    input_tid: int
    grad_tids: dict[str, int]
    meta: dict[str, Any]

    def make_inputs(self, seed: int = 0,
                    scale: float = 0.02) -> dict[int, np.ndarray]:
        """Random host-store contents for every INPUT vertex."""
        rng = np.random.default_rng(seed)
        out: dict[int, np.ndarray] = {}
        for tid, v in self.tg.vertices.items():
            if v.kind == OpKind.INPUT:
                if v.params.get("fill") == "ones":
                    out[tid] = np.ones(v.out.shape, v.out.np_dtype)
                elif v.params.get("fill") == "zeros":
                    out[tid] = np.zeros(v.out.shape, v.out.np_dtype)
                else:
                    out[tid] = (rng.standard_normal(v.out.shape) *
                                scale).astype(v.out.np_dtype)
        return out


class _Tracer:
    """Shared emission helpers over a TaskGraph."""

    def __init__(self, cfg: ArchConfig, tc: TraceConfig):
        self.cfg = cfg
        self.tc = tc
        self.tg = TaskGraph()
        self.dt = tc.dtype
        self.weights: list[int] = []

    # ---- emission helpers -------------------------------------------------
    def w(self, device: int, shape, name: str) -> int:
        tid = self.tg.add_input(device, TensorSpec(tuple(shape), self.dt),
                                name=name)
        self.weights.append(tid)
        return tid

    def op(self, device, op, inputs, shape, *, flops=0.0, name="",
           **params) -> int:
        return self.tg.add_compute(
            device, tuple(inputs), TensorSpec(tuple(shape), self.dt), op=op,
            flops=float(flops), params=params, name=name)

    def bcast(self, x: int, device: int) -> int:
        """Value of x on `device` (transfer vertex if needed)."""
        if self.tg.vertices[x].device == device:
            return x
        return self.tg.add_transfer(device, x,
                                    name=f"bc{ x }→d{device}")

    def reduce_parts(self, parts: list[int], device: int, name: str) -> int:
        """Streaming sum of partial results on `device` (paper §B)."""
        moved = [self.bcast(p, device) for p in parts]
        if len(moved) == 1:
            return moved[0]
        return self.tg.add_reduce(device, moved, streaming=True, name=name)


def _layer_forward(t: _Tracer, x: int, l: int, weights: dict, *,
                   lora: bool, saved: dict | None = None) -> int:
    """Emit one transformer layer; returns the output tid. ``weights`` maps
    names to already-created weight tids (so the backward re-trace reuses
    them). ``saved`` collects intermediate tids for the backward pass."""
    cfg, tc, tg = t.cfg, t.tc, t.tg
    G = tc.n_devices
    S = t.meta_S
    d = cfg.d_model
    H = cfg.n_heads
    dh = cfg.d_head
    hg = min(tc.head_group, H // G) or 1
    J = (H // G) // hg                      # head-groups per device
    hgw = hg * dh
    r = tc.lora_rank
    s_lora = tc.lora_alpha / r
    QB = max(1, S // tc.q_block)
    qb = S // QB
    sv = saved if saved is not None else {}

    # norm 1 + broadcast
    n1_0 = t.op(0, "rmsnorm", (x, weights["g1"]), (S, d),
                flops=5 * S * d, name=f"L{l}.n1")
    sv["n1"] = n1_0
    n1 = {g: t.bcast(n1_0, g) for g in range(G)}
    sv["n1_dev"] = n1

    att_parts = []
    sv["attn"] = {}
    for g in range(G):
        for j in range(J):
            wq, wk, wv, wo = (weights[f"wq{g}.{j}"], weights[f"wk{g}.{j}"],
                              weights[f"wv{g}.{j}"], weights[f"wo{g}.{j}"])
            a = sv["attn"][(g, j)] = {}
            mm = 2 * S * d * hgw
            q = t.op(g, "matmul", (n1[g], wq), (S, hgw), flops=mm,
                     name=f"L{l}.q{g}.{j}")
            k = t.op(g, "matmul", (n1[g], wk), (S, hgw), flops=mm,
                     name=f"L{l}.k{g}.{j}")
            v = t.op(g, "matmul", (n1[g], wv), (S, hgw), flops=mm,
                     name=f"L{l}.v{g}.{j}")
            if lora:
                for nm, base in (("q", q), ("k", k), ("v", v)):
                    A = weights[f"A{nm}{l}"]
                    B = weights[f"B{nm}{g}.{j}"]
                    t1 = t.op(g, "matmul_t", (n1[g], A), (S, r),
                              flops=2 * S * d * r, name=f"L{l}.{nm}lA{g}.{j}")
                    t2 = t.op(g, "matmul_t", (t1, B), (S, hgw),
                              flops=2 * S * r * hgw,
                              name=f"L{l}.{nm}lB{g}.{j}")
                    t2s = t.op(g, "scale", (t2,), (S, hgw), alpha=s_lora,
                               name=f"L{l}.{nm}ls{g}.{j}")
                    new = t.op(g, "add", (base if nm != "q" else q, t2s),
                               (S, hgw), name=f"L{l}.{nm}+{g}.{j}")
                    a[f"t1{nm}"] = t1
                    if nm == "q":
                        q = new
                    elif nm == "k":
                        k = new
                    else:
                        v = new
            q3 = t.op(g, "split_heads", (q,), (hg, S, dh), n_heads=hg,
                      name=f"L{l}.q3{g}.{j}")
            k3 = t.op(g, "split_heads", (k,), (hg, S, dh), n_heads=hg,
                      name=f"L{l}.k3{g}.{j}")
            v3 = t.op(g, "split_heads", (v,), (hg, S, dh), n_heads=hg,
                      name=f"L{l}.v3{g}.{j}")
            a.update(q=q, k=k, v=v, q3=q3, k3=k3, v3=v3, ps=[], o_blocks=[])
            o_blocks = []
            for b in range(QB):
                qblk = t.op(g, "slice_rows_3d", (q3,), (hg, qb, dh),
                            start=b * qb, stop=(b + 1) * qb,
                            name=f"L{l}.qb{g}.{j}.{b}")
                sc = t.op(g, "scores", (qblk, k3), (hg, qb, S),
                          flops=2 * hg * qb * S * dh,
                          scale=1.0 / math.sqrt(dh), causal=True,
                          q_offset=b * qb, name=f"L{l}.s{g}.{j}.{b}")
                p = t.op(g, "softmax", (sc,), (hg, qb, S),
                         flops=5 * hg * qb * S, name=f"L{l}.p{g}.{j}.{b}")
                ob = t.op(g, "attn_out", (p, v3), (hg, qb, dh),
                          flops=2 * hg * qb * S * dh,
                          name=f"L{l}.o{g}.{j}.{b}")
                a["ps"].append((qblk, sc, p, ob))
                o_blocks.append(ob)
            o3 = (o_blocks[0] if QB == 1 else
                  t.op(g, "concat", o_blocks, (hg, S, dh), axis=1,
                       name=f"L{l}.oc{g}.{j}"))
            om = t.op(g, "merge_heads", (o3,), (S, hgw),
                      name=f"L{l}.om{g}.{j}")
            a["o3"], a["om"] = o3, om
            part = t.op(g, "matmul", (om, wo), (S, d), flops=2 * S * hgw * d,
                        name=f"L{l}.ap{g}.{j}")
            att_parts.append(part)
    attn_out = t.reduce_parts(att_parts, 0, f"L{l}.attsum")
    h1 = t.op(0, "add", (x, attn_out), (S, d), name=f"L{l}.h1")
    sv["h1"] = h1

    n2_0 = t.op(0, "rmsnorm", (h1, weights["g2"]), (S, d),
                flops=5 * S * d, name=f"L{l}.n2")
    sv["n2"] = n2_0
    n2 = {g: t.bcast(n2_0, g) for g in range(G)}
    sv["n2_dev"] = n2
    Cs = tc.mlp_slices
    fcw = cfg.d_ff // (G * Cs)
    mlp_parts = []
    sv["mlp"] = {}
    for g in range(G):
        for c in range(Cs):
            wi = weights[f"wi{g}.{c}"]
            wo2 = weights[f"wo2{g}.{c}"]
            m = sv["mlp"][(g, c)] = {}
            u = t.op(g, "matmul", (n2[g], wi), (S, fcw),
                     flops=2 * S * d * fcw, name=f"L{l}.u{g}.{c}")
            if lora:
                Am = weights[f"Am{l}"]
                Bm = weights[f"Bm{g}.{c}"]
                t1 = t.op(g, "matmul_t", (n2[g], Am), (S, r),
                          flops=2 * S * d * r, name=f"L{l}.mlA{g}.{c}")
                t2 = t.op(g, "matmul_t", (t1, Bm), (S, fcw),
                          flops=2 * S * r * fcw, name=f"L{l}.mlB{g}.{c}")
                t2s = t.op(g, "scale", (t2,), (S, fcw), alpha=s_lora,
                           name=f"L{l}.mls{g}.{c}")
                u = t.op(g, "add", (u, t2s), (S, fcw), name=f"L{l}.u+{g}.{c}")
                m["t1"] = t1
            act = t.op(g, "gelu", (u,), (S, fcw), flops=8 * S * fcw,
                       name=f"L{l}.a{g}.{c}")
            part = t.op(g, "matmul", (act, wo2), (S, d),
                        flops=2 * S * fcw * d, name=f"L{l}.mp{g}.{c}")
            m.update(u=u, act=act)
            mlp_parts.append(part)
    mlp_out = t.reduce_parts(mlp_parts, 0, f"L{l}.mlpsum")
    out = t.op(0, "add", (h1, mlp_out), (S, d), name=f"L{l}.out")
    return out


def _make_layer_weights(t: _Tracer, l: int, *, lora: bool) -> dict:
    cfg, tc = t.cfg, t.tc
    G = tc.n_devices
    d, H, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    hg = min(tc.head_group, H // G) or 1
    J = (H // G) // hg
    hgw = hg * dh
    Cs = tc.mlp_slices
    fcw = cfg.d_ff // (G * Cs)
    r = tc.lora_rank
    ws: dict[str, int] = {
        "g1": t.w(0, (d,), f"L{l}.g1"),
        "g2": t.w(0, (d,), f"L{l}.g2"),
    }
    if lora:
        for nm in ("q", "k", "v"):
            ws[f"A{nm}{l}"] = t.w(0, (r, d), f"L{l}.A{nm}")
        ws[f"Am{l}"] = t.w(0, (r, d), f"L{l}.Am")
    for g in range(G):
        for j in range(J):
            for nm in ("wq", "wk", "wv"):
                ws[f"{nm}{g}.{j}"] = t.w(g, (d, hgw), f"L{l}.{nm}{g}.{j}")
            ws[f"wo{g}.{j}"] = t.w(g, (hgw, d), f"L{l}.wo{g}.{j}")
            if lora:
                for nm in ("q", "k", "v"):
                    ws[f"B{nm}{g}.{j}"] = t.w(g, (hgw, r),
                                              f"L{l}.B{nm}{g}.{j}")
        for c in range(Cs):
            ws[f"wi{g}.{c}"] = t.w(g, (d, fcw), f"L{l}.wi{g}.{c}")
            ws[f"wo2{g}.{c}"] = t.w(g, (fcw, d), f"L{l}.wo2{g}.{c}")
            if lora:
                ws[f"Bm{g}.{c}"] = t.w(g, (fcw, r), f"L{l}.Bm{g}.{c}")
    return ws


def trace_prefill(cfg: ArchConfig, *, seq_len: int, n_layers: int | None = None,
                  trace: TraceConfig = TraceConfig()) -> Traced:
    """First-token inference TASKGRAPH (paper §8 task 1, B=1)."""
    t = _Tracer(cfg, trace)
    t.meta_S = seq_len
    x = t.tg.add_input(0, TensorSpec((seq_len, cfg.d_model), trace.dtype),
                       name="x")
    h = x
    L = n_layers if n_layers is not None else cfg.n_layers
    for l in range(L):
        ws = _make_layer_weights(t, l, lora=False)
        h = _layer_forward(t, h, l, ws, lora=False)
    gf = t.w(0, (cfg.d_model,), "gf")
    hn = t.op(0, "rmsnorm", (h, gf), (seq_len, cfg.d_model), name="final_norm")
    last = t.op(0, "slice_rows", (hn,), (1, cfg.d_model), start=seq_len - 1,
                stop=seq_len, name="last_tok")
    wu = t.w(0, (cfg.d_model, cfg.vocab_size), "unembed")
    logits = t.op(0, "matmul", (last, wu), (1, cfg.vocab_size),
                  flops=2 * cfg.d_model * cfg.vocab_size, name="logits")
    return Traced(t.tg, t.weights, x, {}, {
        "kind": "prefill", "seq_len": seq_len, "n_layers": L,
        "logits": logits})


def trace_lora_train(cfg: ArchConfig, *, seq_len: int,
                     n_layers: int | None = None,
                     trace: TraceConfig = TraceConfig()) -> Traced:
    """LoRA fwd+bwd TASKGRAPH (paper §8 task 2). Activation checkpointing:
    only per-layer inputs are kept; layer internals are re-traced in the
    backward section. Gradients for every adapter are graph outputs."""
    t = _Tracer(cfg, trace)
    S = t.meta_S = seq_len
    d = cfg.d_model
    tc = trace
    G = tc.n_devices
    tg = t.tg
    x0 = tg.add_input(0, TensorSpec((S, d), tc.dtype), name="x")
    L = n_layers if n_layers is not None else cfg.n_layers

    layer_ws: list[dict] = []
    layer_in: list[int] = [x0]
    h = x0
    for l in range(L):
        ws = _make_layer_weights(t, l, lora=True)
        layer_ws.append(ws)
        h = _layer_forward(t, h, l, ws, lora=True)
        layer_in.append(h)

    # loss = sum(h_L)  →  dh_L = ones
    dh = tg.add_input(0, TensorSpec((S, d), tc.dtype), name="dloss",
                      op="input", params={"fill": "ones"})
    grads: dict[str, int] = {}

    H, dh_dim = cfg.n_heads, cfg.d_head
    hg = min(tc.head_group, H // G) or 1
    J = (H // G) // hg
    hgw = hg * dh_dim
    Cs = tc.mlp_slices
    fcw = cfg.d_ff // (G * Cs)
    r = tc.lora_rank
    s_lora = tc.lora_alpha / r
    QB = max(1, S // tc.q_block)
    qb = S // QB

    for l in reversed(range(L)):
        ws = layer_ws[l]
        x_l = layer_in[l]
        sv: dict = {}
        _ = _layer_forward(t, x_l, l, ws, lora=True, saved=sv)  # recompute

        # ---- MLP backward ----
        dn2_parts = []
        for g in range(G):
            dout_g = t.bcast(dh, g)
            for c in range(Cs):
                m = sv["mlp"][(g, c)]
                da = t.op(g, "matmul_t", (dout_g, ws[f"wo2{g}.{c}"]),
                          (S, fcw), flops=2 * S * d * fcw,
                          name=f"L{l}.bda{g}.{c}")
                du = t.op(g, "gelu_bwd", (m["u"], da), (S, fcw),
                          flops=10 * S * fcw, name=f"L{l}.bdu{g}.{c}")
                dn2_parts.append(t.op(
                    g, "matmul_t", (du, ws[f"wi{g}.{c}"]), (S, d),
                    flops=2 * S * d * fcw, name=f"L{l}.bdn2{g}.{c}"))
                # LoRA grads (chain through the scale)
                dus = t.op(g, "scale", (du,), (S, fcw), alpha=s_lora,
                           name=f"L{l}.bdus{g}.{c}")
                dBm = t.op(g, "matmul_tn", (dus, m["t1"]), (fcw, r),
                           flops=2 * S * fcw * r, name=f"L{l}.gBm{g}.{c}")
                grads[f"Bm{l}.{g}.{c}"] = dBm
                dt1 = t.op(g, "matmul", (dus, ws[f"Bm{g}.{c}"]), (S, r),
                           flops=2 * S * fcw * r, name=f"L{l}.bdt1m{g}.{c}")
                dn2_parts.append(t.op(
                    g, "matmul", (dt1, ws[f"Am{l}"]), (S, d),
                    flops=2 * S * r * d, name=f"L{l}.bdn2l{g}.{c}"))
                gAm = t.op(g, "matmul_tn", (dt1, sv["n2_dev"][g]), (r, d),
                           flops=2 * S * r * d, name=f"L{l}.gAmp{g}.{c}")
                grads.setdefault(f"Am{l}__parts", [])
                grads[f"Am{l}__parts"].append(gAm)
        dn2 = t.reduce_parts(dn2_parts, 0, f"L{l}.bdn2sum")
        grads[f"Am{l}"] = t.reduce_parts(grads.pop(f"Am{l}__parts"), 0,
                                         f"L{l}.gAmsum")
        dn2b = t.op(0, "rmsnorm_bwd", (sv["h1"], ws["g2"], dn2), (S, d),
                    flops=10 * S * d, name=f"L{l}.bn2")
        dh1 = t.op(0, "add", (dh, dn2b), (S, d), name=f"L{l}.bdh1")

        # ---- attention backward ----
        dn1_parts = []
        dAq_parts: dict[str, list[int]] = {"q": [], "k": [], "v": []}
        for g in range(G):
            dh1_g = t.bcast(dh1, g)
            for j in range(J):
                a = sv["attn"][(g, j)]
                dom = t.op(g, "matmul_t", (dh1_g, ws[f"wo{g}.{j}"]), (S, hgw),
                           flops=2 * S * d * hgw, name=f"L{l}.bdo{g}.{j}")
                do3 = t.op(g, "split_heads", (dom,), (hg, S, dh_dim),
                           n_heads=hg, name=f"L{l}.bdo3{g}.{j}")
                dq_blocks = []
                dk_parts, dv_parts = [], []
                for b, (qblk, sc, p, ob) in enumerate(a["ps"]):
                    dob = t.op(g, "slice_rows_3d", (do3,), (hg, qb, dh_dim),
                               start=b * qb, stop=(b + 1) * qb,
                               name=f"L{l}.bdob{g}.{j}.{b}")
                    dp = t.op(g, "matmul_t", (dob, a["v3"]), (hg, qb, S),
                              flops=2 * hg * qb * S * dh_dim,
                              name=f"L{l}.bdp{g}.{j}.{b}")
                    ds = t.op(g, "softmax_bwd", (p, dp), (hg, qb, S),
                              flops=6 * hg * qb * S,
                              name=f"L{l}.bds{g}.{j}.{b}")
                    dss = t.op(g, "scale", (ds,), (hg, qb, S),
                               alpha=1.0 / math.sqrt(dh_dim),
                               name=f"L{l}.bdss{g}.{j}.{b}")
                    dq_blocks.append(t.op(
                        g, "matmul", (dss, a["k3"]), (hg, qb, dh_dim),
                        flops=2 * hg * qb * S * dh_dim,
                        name=f"L{l}.bdq{g}.{j}.{b}"))
                    dk_parts.append(t.op(
                        g, "matmul_tn", (dss, qblk), (hg, S, dh_dim),
                        flops=2 * hg * qb * S * dh_dim,
                        name=f"L{l}.bdk{g}.{j}.{b}"))
                    dv_parts.append(t.op(
                        g, "matmul_tn", (p, dob), (hg, S, dh_dim),
                        flops=2 * hg * qb * S * dh_dim,
                        name=f"L{l}.bdv{g}.{j}.{b}"))
                dq3 = (dq_blocks[0] if QB == 1 else
                       t.op(g, "concat", dq_blocks, (hg, S, dh_dim), axis=1,
                            name=f"L{l}.bdqc{g}.{j}"))
                dk3 = t.reduce_parts(dk_parts, g, f"L{l}.bdksum{g}.{j}")
                dv3 = t.reduce_parts(dv_parts, g, f"L{l}.bdvsum{g}.{j}")
                for nm, d3 in (("q", dq3), ("k", dk3), ("v", dv3)):
                    dm = t.op(g, "merge_heads", (d3,), (S, hgw),
                              name=f"L{l}.bdm{nm}{g}.{j}")
                    dn1_parts.append(t.op(
                        g, "matmul_t", (dm, ws[f"w{nm}{g}.{j}"]), (S, d),
                        flops=2 * S * d * hgw,
                        name=f"L{l}.bdn1{nm}{g}.{j}"))
                    dms = t.op(g, "scale", (dm,), (S, hgw), alpha=s_lora,
                               name=f"L{l}.bdms{nm}{g}.{j}")
                    grads[f"B{nm}{l}.{g}.{j}"] = t.op(
                        g, "matmul_tn", (dms, a[f"t1{nm}"]), (hgw, r),
                        flops=2 * S * hgw * r, name=f"L{l}.gB{nm}{g}.{j}")
                    dt1 = t.op(g, "matmul", (dms, ws[f"B{nm}{g}.{j}"]),
                               (S, r), flops=2 * S * hgw * r,
                               name=f"L{l}.bdt1{nm}{g}.{j}")
                    dn1_parts.append(t.op(
                        g, "matmul", (dt1, ws[f"A{nm}{l}"]), (S, d),
                        flops=2 * S * r * d, name=f"L{l}.bdn1l{nm}{g}.{j}"))
                    dAq_parts[nm].append(t.op(
                        g, "matmul_tn", (dt1, sv["n1_dev"][g]), (r, d),
                        flops=2 * S * r * d, name=f"L{l}.gA{nm}p{g}.{j}"))
        for nm in ("q", "k", "v"):
            grads[f"A{nm}{l}"] = t.reduce_parts(
                dAq_parts[nm], 0, f"L{l}.gA{nm}sum")
        dn1 = t.reduce_parts(dn1_parts, 0, f"L{l}.bdn1sum")
        dn1b = t.op(0, "rmsnorm_bwd", (x_l, ws["g1"], dn1), (S, d),
                    flops=10 * S * d, name=f"L{l}.bn1")
        dh = t.op(0, "add", (dh1, dn1b), (S, d), name=f"L{l}.bdx")

    return Traced(t.tg, t.weights, x0, grads, {
        "kind": "lora_train", "seq_len": S, "n_layers": L})
