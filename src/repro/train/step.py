"""Train-step factory: value_and_grad + optimizer, optional microbatch
gradient accumulation (scan), remat handled inside the model."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from .optim import AdamW, apply_updates


def init_train_state(model, key, optimizer=None) -> dict:
    params = model.init(key)
    opt = (optimizer or AdamW()).init(params)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def make_train_step(model, optimizer=None, *, grad_accum: int = 1,
                    loss_fn: Callable | None = None) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``grad_accum > 1`` splits the (local) batch into microbatches and
    accumulates grads with a ``lax.scan`` — constant memory in the number of
    microbatches."""
    opt = optimizer or AdamW()
    lfn = loss_fn or (lambda params, batch: model.loss(params, batch))

    def compute_grads(params, batch):
        if grad_accum == 1:
            return jax.value_and_grad(lfn)(params, batch)

        def micro(c, mb):
            loss_acc, g_acc = c
            l, g = jax.value_and_grad(lfn)(params, mb)
            return (loss_acc + l, jax.tree.map(jnp.add, g_acc, g)), None

        mbs = jax.tree.map(
            lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                *x.shape[1:]), batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros((), jnp.float32), g0),
                                        mbs)
        scale = 1.0 / grad_accum
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        loss, grads = compute_grads(state["params"], batch)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        updates, opt_state = opt.update(grads, state["opt"], state["params"])
        params = apply_updates(state["params"], updates)
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
