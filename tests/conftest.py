import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402

from repro.core import lockcheck  # noqa: E402


@pytest.fixture(autouse=True)
def _lock_order_sanitizer():
    """Debug-mode lock-order sanitizer (DESIGN.md §13): every test records
    the (held lock class → acquired lock class) pairs its threads take
    across HostPool / TieredStore / DiskStore / the serving engine, and
    fails if the acquisition graph has a cycle — a deadlock that would
    need an exact interleaving to bite, caught on any schedule."""
    lockcheck.reset()
    lockcheck.enable()
    yield
    lockcheck.disable()
    lockcheck.assert_acyclic()
