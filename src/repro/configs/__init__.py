"""Config registry: the 10 assigned architectures + the paper's LLaMA models.

``get_arch(name)`` resolves any registered ``--arch <id>``.
"""
from .base import (ArchConfig, ShapeConfig, SHAPES, input_specs, reduced,
                   applicable_shapes)

from .internvl2_26b import CONFIG as internvl2_26b
from .zamba2_7b import CONFIG as zamba2_7b
from .rwkv6_7b import CONFIG as rwkv6_7b
from .starcoder2_3b import CONFIG as starcoder2_3b
from .qwen2_5_3b import CONFIG as qwen2_5_3b
from .olmo_1b import CONFIG as olmo_1b
from .qwen1_5_32b import CONFIG as qwen1_5_32b
from .granite_moe_1b import CONFIG as granite_moe_1b
from .moonshot_16b import CONFIG as moonshot_16b
from .seamless_m4t_large import CONFIG as seamless_m4t_large
from .llama_7b import CONFIG as llama_7b
from .llama_65b import CONFIG as llama_65b

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in (
        internvl2_26b, zamba2_7b, rwkv6_7b, starcoder2_3b, qwen2_5_3b,
        olmo_1b, qwen1_5_32b, granite_moe_1b, moonshot_16b,
        seamless_m4t_large, llama_7b, llama_65b,
    )
}
ASSIGNED = [n for n in ARCHS if not n.startswith("llama")]


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}"
                       ) from None


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCHS", "ASSIGNED",
           "get_arch", "input_specs", "reduced", "applicable_shapes"]
