"""Debug-mode lock-order sanitizer (the dynamic complement to the static
plan certifier, DESIGN.md §13).

The runtime's locking discipline is documented but was only enforced by
review: ``HostPool._lock`` is a leaf (consumers charge/release while
holding their own store locks; arbitration callbacks fire outside it),
``DiskStore._lock`` is a leaf under ``TieredStore``'s store lock, and
the serving engine's ``_revoke_lock`` is a leaf under the engine lock.
This module turns the discipline into an assertion: every lock the
inventory cares about is a :class:`SanitizedLock`; while enabled (tests
only — one branch on a module flag otherwise), each acquisition records
``held-class → acquired-class`` edges with the acquiring thread, and
:func:`assert_acyclic` fails with the concrete cycle and example threads
if two code paths ever take the same pair of lock classes in opposite
orders — a deadlock that needs exact interleaving to bite, caught on any
schedule.

``SanitizedLock`` satisfies the ``threading.Lock`` protocol including
what ``threading.Condition`` needs, so instrumented locks keep backing
condition variables.

**Wait-awareness.** ``Condition.wait()`` *releases* the lock while
sleeping and reacquires it afterwards. Recorded naively, that reacquire
looks like a fresh acquisition: any lock still held below the waited-on
one on the thread's stack would grow a ``inner → outer`` edge — the
exact inverse of the real ``outer → inner`` nesting of the same single
code path, closing a false cycle that cannot deadlock (the waiter gave
the outer lock up; nothing is held-and-wanted in both directions).
``SanitizedLock`` therefore implements the private hooks
``threading.Condition`` probes for (``_release_save`` /
``_acquire_restore`` / ``_is_owned``): the wait-release remembers the
lock's position on the held stack, and the post-notify reacquire
reinserts it *at that position without recording any edge* — a
resumption of an already-audited hold, not a new ordering decision.
"""
from __future__ import annotations

import threading
from typing import Any

__all__ = ["SanitizedLock", "LockOrderError", "make_lock", "enable",
           "disable", "reset", "enabled", "edges", "assert_acyclic"]


class LockOrderError(AssertionError):
    """Two lock classes were acquired in both orders (deadlock hazard)."""


_enabled = False
_reg_lock = threading.Lock()          # guards the edge registry (leaf)
_edges: dict[str, set[str]] = {}      # held class -> then-acquired class
_examples: dict[tuple[str, str], str] = {}   # edge -> first thread seen
_tls = threading.local()


def enable() -> None:
    """Start recording acquisition-order edges (test fixtures)."""
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def reset() -> None:
    """Forget all recorded edges (per-test isolation)."""
    with _reg_lock:
        _edges.clear()
        _examples.clear()


def enabled() -> bool:
    return _enabled


def edges() -> dict[str, set[str]]:
    """Snapshot of the acquisition graph (held class -> acquired class)."""
    with _reg_lock:
        return {k: set(v) for k, v in _edges.items()}


def _held_stack() -> list[str]:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


def _record_acquire(cls: str) -> None:
    stack = _held_stack()
    if stack:
        thread = threading.current_thread().name
        with _reg_lock:
            for held in stack:
                if held != cls:
                    _edges.setdefault(held, set()).add(cls)
                    _examples.setdefault((held, cls), thread)
    stack.append(cls)


def _record_release(cls: str) -> int:
    stack = _held_stack()
    # releases need not be LIFO (condition waits, hand-over-hand): drop
    # the most recent matching hold. Returns the stack position the hold
    # occupied so a wait-release can restore it exactly.
    for i in range(len(stack) - 1, -1, -1):
        if stack[i] == cls:
            del stack[i]
            return i
    return len(stack)


def _record_wait_reacquire(cls: str, pos: int) -> None:
    """Reinsert a wait-released hold at its saved stack position WITHOUT
    recording edges: the thread never chose a new acquisition order — it
    resumed a hold that was already audited when first taken."""
    stack = _held_stack()
    stack.insert(min(pos, len(stack)), cls)


class SanitizedLock:
    """A ``threading.Lock`` that reports its acquisition order while the
    sanitizer is enabled. ``lock_class`` names the *role* of the lock
    (e.g. ``"HostPool"``), not the instance: ordering bugs are between
    code paths, and all instances of a role share them."""

    __slots__ = ("_lk", "lock_class")

    def __init__(self, lock_class: str) -> None:
        self._lk = threading.Lock()
        self.lock_class = lock_class

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._lk.acquire(blocking, timeout)
        if got and _enabled:
            _record_acquire(self.lock_class)
        return got

    def release(self) -> None:
        self._lk.release()
        if _enabled:
            _record_release(self.lock_class)

    def locked(self) -> bool:
        return self._lk.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc: Any) -> None:
        self.release()

    # ---- hooks threading.Condition binds via hasattr ------------------
    # Making the wait-release/reacquire pair visible keeps the held stack
    # truthful across Condition.wait() and — crucially — keeps the
    # reacquire from recording edges (see module docstring: a wait
    # resumes an audited hold, it does not pick a new order).
    def _release_save(self) -> Any:
        pos = _record_release(self.lock_class) if _enabled else None
        self._lk.release()
        return pos

    def _acquire_restore(self, state: Any) -> None:
        self._lk.acquire()
        if _enabled:
            _record_wait_reacquire(
                self.lock_class,
                state if state is not None else len(_held_stack()))

    def _is_owned(self) -> bool:
        # probe the raw lock (not the recording acquire): a Condition
        # bookkeeping check must never grow audit edges
        if self._lk.acquire(False):
            self._lk.release()
            return False
        return True

    def __repr__(self) -> str:
        return f"<SanitizedLock {self.lock_class!r} at {id(self):#x}>"


def make_lock(lock_class: str) -> SanitizedLock:
    return SanitizedLock(lock_class)


def assert_acyclic() -> None:
    """Raise :class:`LockOrderError` with the offending cycle if the
    recorded acquisition graph has one. Cheap: the graph has one node
    per lock *class*, not per instance."""
    graph = edges()
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {n: WHITE for n in graph}
    stack: list[str] = []

    def visit(n: str) -> list[str] | None:
        color[n] = GRAY
        stack.append(n)
        for m in sorted(graph.get(n, ())):
            c = color.setdefault(m, WHITE)
            if c == GRAY:
                return stack[stack.index(m):] + [m]
            if c == WHITE:
                cyc = visit(m)
                if cyc is not None:
                    return cyc
        color[n] = BLACK
        stack.pop()
        return None

    for n in sorted(graph):
        if color[n] == WHITE:
            cyc = visit(n)
            if cyc is not None:
                with _reg_lock:
                    ex = {f"{a}->{b}": _examples.get((a, b), "?")
                          for a, b in zip(cyc, cyc[1:])}
                raise LockOrderError(
                    f"lock acquisition order cycle: {' -> '.join(cyc)} "
                    f"(first seen on threads {ex}) — two code paths take "
                    f"these lock classes in opposite orders")
