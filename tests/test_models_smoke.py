"""Per-arch smoke tests (deliverable f): every assigned architecture as a
REDUCED same-family config — one forward/train step + one decode step on
CPU, asserting shapes and no NaNs; plus decode-vs-prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch, reduced
from repro.models import build_model

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jnp.ones((B, S, cfg.d_model), "float32")
    if cfg.frontend == "vit":
        batch["vision_embeds"] = jnp.ones(
            (B, cfg.n_frontend_tokens, cfg.d_model), "float32")
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_train_step(arch):
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.isfinite(g).all()), f"{arch}: NaN grad at {path}"
    # one optimizer step changes the loss
    from repro.train.optim import AdamW, apply_updates
    opt = AdamW(lr=1e-2)
    upd, _ = opt.update(grads, opt.init(params), params)
    loss2 = model.loss(apply_updates(params, upd), batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_step_shapes(arch):
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    B = 2
    cache = (model.init_cache(B, 32, 16) if cfg.family == "encdec"
             else model.init_cache(B, 32))
    logits, cache2 = jax.jit(model.decode_step)(
        params, cache, jnp.zeros((B, 1), "int32"), jnp.asarray(3, "int32"))
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN decode logits"
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "olmo-1b", "starcoder2-3b",
                                  "rwkv6-7b", "zamba2-7b",
                                  "granite-moe-1b-a400m"])
def test_decode_matches_prefill(arch):
    """Step-by-step decode logits == teacher-forced full-sequence logits."""
    cfg = reduced(get_arch(arch))
    model = build_model(cfg, moe_capacity_factor=None)   # dropless: exact
    params = model.init(KEY)
    B, S = 2, 10
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = model.apply(params, toks)
    cache = model.init_cache(B, 16)
    step = jax.jit(model.decode_step)
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t:t + 1],
                             jnp.asarray(t, "int32"))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_long_context_applicability():
    """Assignment rule: long_500k only for sub-quadratic archs."""
    from repro.configs import applicable_shapes
    assert "long_500k" in applicable_shapes(get_arch("rwkv6-7b"))
    assert "long_500k" in applicable_shapes(get_arch("zamba2-7b"))
    assert "long_500k" not in applicable_shapes(get_arch("qwen1.5-32b"))
    assert "long_500k" not in applicable_shapes(get_arch("internvl2-26b"))


def test_param_counts_roughly_match_names():
    """Sanity: analytic parameter counts are in the advertised ballpark."""
    approx = {
        "internvl2-26b": (18e9, 30e9),    # LM backbone of the 26B VLM
        "zamba2-7b": (5e9, 9e9),
        "rwkv6-7b": (5e9, 9e9),
        "starcoder2-3b": (2e9, 4e9),
        "qwen1.5-32b": (25e9, 40e9),
        "olmo-1b": (0.8e9, 1.6e9),
        "moonshot-v1-16b-a3b": (20e9, 30e9),  # assignment cfg arithmetic
    }
    for name, (lo, hi) in approx.items():
        n = get_arch(name).param_count
        assert lo < n < hi, f"{name}: {n:.2e} outside [{lo:.0e},{hi:.0e}]"
