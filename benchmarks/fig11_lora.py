"""Paper Fig. 11/13: LoRA training time per batch (fwd+bwd, activation
checkpointing) under constrained device RAM — TURNIP vs fixed-execution."""
from __future__ import annotations

from repro.configs import get_arch
from repro.core import BuildConfig, MemgraphOOM, build_memgraph
from repro.core.simulate import simulate
from repro.core.trace import TraceConfig, trace_lora_train

from .common import P100_SERVER, emit


def run(tokens=(1024, 2048), budget_gb=(16.0, 2.5), arch="llama-7b",
        n_layers=3, quick=False) -> list[dict]:
    cfg = get_arch(arch)
    srv = P100_SERVER
    rows = []
    if quick:
        tokens, budget_gb = tokens[:1], budget_gb[:2]
    for T in tokens:
        tr = trace_lora_train(cfg, seq_len=T, n_layers=n_layers,
                              trace=TraceConfig(
                                  n_devices=srv["n_devices"], head_group=8,
                                  q_block=max(512, T // 2), mlp_slices=2,
                                  dtype="float16"))
        for budget in budget_gb:
            cap = int(budget * 2**30 * n_layers / cfg.n_layers)
            try:
                res = build_memgraph(tr.tg, BuildConfig(capacity=cap))
            except MemgraphOOM:
                rows.append(dict(tokens=T, budget=budget, mode="turnip",
                                 status="OOM", s=None))
                emit(f"fig11/{arch}/T{T}/mem{budget:g}GB/turnip", 0.0, "OOM")
                continue
            scale = cfg.n_layers / n_layers
            for mode, label in (("nondet", "turnip"),
                                ("fixed", "turnip-fixed")):
                sim = simulate(res.memgraph, srv["hw"], mode=mode)
                full = sim.makespan * scale
                rows.append(dict(tokens=T, budget=budget, mode=label,
                                 status="ok", s=full,
                                 reloads=res.n_reloads))
                emit(f"fig11/{arch}/T{T}/mem{budget:g}GB/{label}",
                     full * 1e6, f"rel={res.n_reloads}")
    return rows


if __name__ == "__main__":
    run()
